"""Doc-snippet smoke runner: extract fenced ```python blocks from the
given markdown files and execute them (``make docs``).

Blocks within one file share a namespace and run top to bottom, so a doc
can build up state across snippets like a doctest session. Snippets are
expected to be CPU-fast (small shapes, interpret-mode kernels) — this is
a correctness gate for the documentation, not a benchmark. A block
fenced as ```python no-run is skipped (for illustrative fragments that
are not self-contained).

    PYTHONPATH=src python tools/check_docs.py README.md docs/*.md
"""

from __future__ import annotations

import pathlib
import re
import sys
import traceback

FENCE = re.compile(r"^```python[ \t]*(no-run)?[ \t]*\n(.*?)^```[ \t]*$",
                   re.S | re.M)


def run_file(path: pathlib.Path) -> tuple[int, int]:
    """Execute every runnable python block in ``path``; return
    (blocks_run, failures)."""
    ns: dict = {"__name__": f"docsnippet:{path.name}"}
    ran = failed = 0
    text = path.read_text()
    for i, m in enumerate(FENCE.finditer(text)):
        if m.group(1):  # no-run
            continue
        block = m.group(2)
        line = text[: m.start(2)].count("\n") + 1
        try:
            code = compile("\n" * (line - 1) + block, str(path), "exec")
            exec(code, ns)  # noqa: S102 - the whole point of this tool
            ran += 1
        except Exception:
            failed += 1
            print(f"FAIL {path}#block{i} (line {line}):", file=sys.stderr)
            traceback.print_exc()
    return ran, failed


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    total = failures = 0
    for arg in argv:
        path = pathlib.Path(arg)
        ran, failed = run_file(path)
        total += ran
        failures += failed
        status = "ok" if not failed else f"{failed} FAILED"
        print(f"{path}: {ran} snippet(s) {status}")
    if failures:
        return 1
    if total == 0:
        print("no runnable snippets found", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
