# Convenience targets. Tier-1 verification is `make check`.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: check test kernel-parity docs bench bench-json bench-smoke \
	autotune serve-gate dist-selftest

# tier-1 tests + interpret-mode kernel parity + doc-snippet smoke + the
# CI-sized bench schema gate + both dispatch paths of the paged serving
# stack + the distributed selftest at 1 and 8 forced host devices (the
# kernel parity suites are part of tier-1; all are also runnable
# standalone below)
check: test kernel-parity docs bench-smoke serve-gate dist-selftest

test:
	$(PY) -m pytest -x -q

# interpret-mode Pallas kernels vs jnp oracles only (fast inner loop
# while iterating on kernels)
kernel-parity:
	$(PY) -m pytest -q tests/test_kernels.py tests/test_int_reconstruct.py \
		tests/test_lns_kernel.py tests/test_takum_attention.py \
		tests/test_paged_attention.py

# the paged-serving scheduler under both attention dispatch paths: the
# jnp oracle (=0) and the interpret-mode Pallas kernel (=1). The env is
# read at import, so each setting is its own pytest process. Covers the
# parity pins, the scheduler fuzz (priorities / chunked prefill /
# per-request sampling / failure events vs solo lockstep + key-schedule
# replay), the prefix-cache property harness (refcount/COW/quarantine
# invariants, device-free), the failure-model suite (preemption,
# deadlines/cancel, NaR fault injection + chaos acceptance), and the
# observability suite (obs-on/off token parity, span-tree completeness,
# metric invariants, Perfetto export).
serve-gate:
	REPRO_KV_ATTN_KERNEL=0 $(PY) -m pytest -q tests/test_serve_scheduler.py \
		tests/test_scheduler_fuzz.py tests/test_prefix_cache.py \
		tests/test_page_pool.py tests/test_faults.py \
		tests/test_serve_sharded.py tests/test_obs.py
	REPRO_KV_ATTN_KERNEL=1 $(PY) -m pytest -q tests/test_serve_scheduler.py \
		tests/test_scheduler_fuzz.py tests/test_prefix_cache.py \
		tests/test_page_pool.py tests/test_faults.py \
		tests/test_serve_sharded.py tests/test_obs.py

# execute the fenced python snippets in the documentation (doctest-style
# smoke: the docs cannot drift from the code silently) + the runnable
# continuous-batching, shared-prefix and failure-model examples
docs:
	$(PY) tools/check_docs.py README.md docs/*.md
	$(PY) examples/serve_continuous.py
	$(PY) examples/serve_prefix.py
	$(PY) examples/serve_faults.py
	$(PY) examples/serve_sharded.py
	REPRO_OBS=2 $(PY) examples/serve_traced.py

bench:
	$(PY) -m benchmarks.run

# perf trajectory artifact only (decode/encode/qmatmul -> BENCH_codec.json)
bench-json:
	$(PY) -m benchmarks.run --only codec_json

# CI-sized pass over every BENCH_codec row (schema + dataflow gate on
# CPU JAX; writes BENCH_codec.smoke.json, never the real artifact).
# REPRO_AUTOTUNE=1 is lookup-only: CI validates the checked-in autotune
# table without ever paying for a sweep. The gate asserts schema 8: a
# `blocks` entry on every kernel row + the shared-prefix serving row
# pair with a nonzero warm-tree prefix_hit_rate + the serving_faults
# rows (preemption fires when enabled, NaR injection is contained) +
# the serving_sharded rows (compressed collectives move strictly fewer
# interconnect bytes than f32; tp=8 normalized throughput >= tp=1).
bench-smoke:
	REPRO_AUTOTUNE=1 $(PY) -m benchmarks.codec_json --smoke
	$(PY) tools/check_bench_schema.py BENCH_codec.smoke.json

# sweep the kernel block spaces at the BENCH shapes on this backend and
# write the local cache (.repro_autotune.json); add --write-defaults via
# AUTOTUNE_FLAGS to merge into the checked-in table
autotune:
	REPRO_AUTOTUNE=force $(PY) -m repro.kernels.autotune $(AUTOTUNE_FLAGS)

# the collective/sharding selftest at both ends of the forced
# host-device range: 1 (size-1 identity collectives, the laptop case)
# and 8 (the ring + param-spec + annotate checks the serving mesh uses)
dist-selftest:
	REPRO_HOST_DEVICES=1 $(PY) -m repro.dist.selftest
	REPRO_HOST_DEVICES=8 $(PY) -m repro.dist.selftest
