# Convenience targets. Tier-1 verification is `make check`.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: check test kernel-parity docs bench bench-json bench-smoke \
	dist-selftest

# tier-1 tests + interpret-mode kernel parity + doc-snippet smoke + the
# CI-sized bench schema gate (the kernel parity suites are part of
# tier-1; all are also runnable standalone below)
check: test kernel-parity docs bench-smoke

test:
	$(PY) -m pytest -x -q

# interpret-mode Pallas kernels vs jnp oracles only (fast inner loop
# while iterating on kernels)
kernel-parity:
	$(PY) -m pytest -q tests/test_kernels.py tests/test_int_reconstruct.py \
		tests/test_lns_kernel.py tests/test_takum_attention.py

# execute the fenced python snippets in the documentation (doctest-style
# smoke: the docs cannot drift from the code silently)
docs:
	$(PY) tools/check_docs.py README.md docs/*.md

bench:
	$(PY) -m benchmarks.run

# perf trajectory artifact only (decode/encode/qmatmul -> BENCH_codec.json)
bench-json:
	$(PY) -m benchmarks.run --only codec_json

# CI-sized pass over every BENCH_codec row (schema + dataflow gate on
# CPU JAX; writes BENCH_codec.smoke.json, never the real artifact)
bench-smoke:
	$(PY) -m benchmarks.codec_json --smoke

dist-selftest:
	$(PY) -m repro.dist.selftest
