# Convenience targets. Tier-1 verification is `make check`.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: check test kernel-parity docs bench bench-json bench-smoke \
	autotune serve-gate dist-selftest

# tier-1 tests + interpret-mode kernel parity + doc-snippet smoke + the
# CI-sized bench schema gate + both dispatch paths of the paged serving
# stack (the kernel parity suites are part of tier-1; all are also
# runnable standalone below)
check: test kernel-parity docs bench-smoke serve-gate

test:
	$(PY) -m pytest -x -q

# interpret-mode Pallas kernels vs jnp oracles only (fast inner loop
# while iterating on kernels)
kernel-parity:
	$(PY) -m pytest -q tests/test_kernels.py tests/test_int_reconstruct.py \
		tests/test_lns_kernel.py tests/test_takum_attention.py \
		tests/test_paged_attention.py

# the paged-serving scheduler under both attention dispatch paths: the
# jnp oracle (=0) and the interpret-mode Pallas kernel (=1). The env is
# read at import, so each setting is its own pytest process. Covers the
# parity pins, the scheduler fuzz (priorities / chunked prefill /
# per-request sampling / failure events vs solo lockstep + key-schedule
# replay), the prefix-cache property harness (refcount/COW/quarantine
# invariants, device-free), and the failure-model suite (preemption,
# deadlines/cancel, NaR fault injection + chaos acceptance).
serve-gate:
	REPRO_KV_ATTN_KERNEL=0 $(PY) -m pytest -q tests/test_serve_scheduler.py \
		tests/test_scheduler_fuzz.py tests/test_prefix_cache.py \
		tests/test_page_pool.py tests/test_faults.py
	REPRO_KV_ATTN_KERNEL=1 $(PY) -m pytest -q tests/test_serve_scheduler.py \
		tests/test_scheduler_fuzz.py tests/test_prefix_cache.py \
		tests/test_page_pool.py tests/test_faults.py

# execute the fenced python snippets in the documentation (doctest-style
# smoke: the docs cannot drift from the code silently) + the runnable
# continuous-batching, shared-prefix and failure-model examples
docs:
	$(PY) tools/check_docs.py README.md docs/*.md
	$(PY) examples/serve_continuous.py
	$(PY) examples/serve_prefix.py
	$(PY) examples/serve_faults.py

bench:
	$(PY) -m benchmarks.run

# perf trajectory artifact only (decode/encode/qmatmul -> BENCH_codec.json)
bench-json:
	$(PY) -m benchmarks.run --only codec_json

# CI-sized pass over every BENCH_codec row (schema + dataflow gate on
# CPU JAX; writes BENCH_codec.smoke.json, never the real artifact).
# REPRO_AUTOTUNE=1 is lookup-only: CI validates the checked-in autotune
# table without ever paying for a sweep. The gate asserts schema 7: a
# `blocks` entry on every kernel row + the shared-prefix serving row
# pair with a nonzero warm-tree prefix_hit_rate + the serving_faults
# rows (preemption fires when enabled, NaR injection is contained).
bench-smoke:
	REPRO_AUTOTUNE=1 $(PY) -m benchmarks.codec_json --smoke
	$(PY) tools/check_bench_schema.py BENCH_codec.smoke.json

# sweep the kernel block spaces at the BENCH shapes on this backend and
# write the local cache (.repro_autotune.json); add --write-defaults via
# AUTOTUNE_FLAGS to merge into the checked-in table
autotune:
	REPRO_AUTOTUNE=force $(PY) -m repro.kernels.autotune $(AUTOTUNE_FLAGS)

dist-selftest:
	$(PY) -m repro.dist.selftest
